"""Out-of-core store + streaming construction invariants (docs/streaming.md).

The load-bearing contract: WHERE the rows live must be invisible to the
math. A disk-backed ``ArrayStore`` and an in-RAM ``MemoryStore`` holding
the same rows must produce bit-identical structures, fits and
predictions (the IO layer adds zero numerical change), and the chunked
likelihood dispatch must match the monolithic in-core program to 1e-10
(only float summation ORDER differs). The same invisibility extends to
the inner-loop memory TIERS: a piece served from the device-resident
spool cache, through the prefetched H2D pipeline, or from cold disk
must produce the identical fit bitwise. Plus: store round-trip/manifest
integrity, chunk-iterator boundary cases, single-batch mini-batch
k-means == Lloyd, a bounded-RSS 200k-point smoke fit, and the
subprocess 8-device distributed streaming fit.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.fit import fit_sbv
from repro.core.pipeline import SBVConfig
from repro.core.predict import predict_sbv
from repro.data.gp_sim import paper_synthetic
from repro.data.store import ArrayStore, MemoryStore, as_store, is_store

pytestmark = pytest.mark.streaming


@pytest.fixture(scope="module")
def small():
    x, y, params = paper_synthetic(seed=0, n=1500, d=4)
    return x, y, params


def _params_equal(a, b):
    return max(
        np.abs(np.asarray(getattr(a, f)) - np.asarray(getattr(b, f))).max()
        for f in ("log_sigma2", "log_beta", "log_nugget")
    )


# -- store round-trip and manifest integrity ------------------------------


def test_store_roundtrip_and_gather(tmp_path, small):
    x, y, _ = small
    st = ArrayStore.from_arrays(str(tmp_path / "s"), x, y, shard_rows=400)
    assert (st.n_rows, st.d, st.n_shards) == (1500, 4, 4)
    st.verify()
    xa, ya = st.read_all()
    assert np.array_equal(xa, x) and np.array_equal(ya, y)
    # Order-preserving gather across shards, duplicates included.
    idx = np.array([1499, 0, 401, 400, 399, 401])
    xg, yg = st.read_rows(idx)
    assert np.array_equal(xg, x[idx]) and np.array_equal(yg, y[idx])
    with pytest.raises(IndexError):
        st.read_rows(np.array([1500]))
    assert is_store(st) and is_store(MemoryStore(x, y)) and not is_store(x)
    assert as_store(st) is st


def test_writer_appends_span_shards(tmp_path, small):
    x, y, _ = small
    with ArrayStore.create(str(tmp_path / "w"), 4, shard_rows=512) as w:
        for a in range(0, 1500, 613):  # deliberately shard-misaligned
            w.append(x[a:a + 613], y[a:a + 613])
    st = ArrayStore(str(tmp_path / "w"))
    assert st.n_rows == 1500 and st.n_shards == 3
    xa, ya = st.read_all()
    assert np.array_equal(xa, x) and np.array_equal(ya, y)


def test_manifest_integrity_checks(tmp_path, small):
    x, y, _ = small
    path = str(tmp_path / "m")
    ArrayStore.from_arrays(path, x, y, shard_rows=400)
    with open(os.path.join(path, "manifest.json")) as f:
        m = json.load(f)
    m["n_rows"] = 9999
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(m, f)
    with pytest.raises(ValueError, match="corrupt manifest"):
        ArrayStore(path)
    m["n_rows"] = 1500
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(m, f)
    os.remove(os.path.join(path, "x_00002.npy"))
    with pytest.raises(FileNotFoundError, match="missing shards"):
        ArrayStore(path)
    with pytest.raises(FileNotFoundError):
        ArrayStore(str(tmp_path / "not-a-store"))


def test_iter_chunks_boundaries(tmp_path, small):
    x, y, _ = small
    st = ArrayStore.from_arrays(str(tmp_path / "c"), x, y, shard_rows=400)
    # Ragged last window, windows spanning shard boundaries.
    ws = list(st.iter_chunks(700))
    assert [w[0] for w in ws] == [0, 700, 1400]
    assert [w[1].shape[0] for w in ws] == [700, 700, 100]
    assert np.array_equal(np.concatenate([w[1] for w in ws]), x)
    # Degenerate single-chunk case (rows >= n).
    ws = list(st.iter_chunks(10_000))
    assert len(ws) == 1 and ws[0][1].shape[0] == 1500
    # Default window = manifest shard size.
    assert [w[1].shape[0] for w in st.iter_chunks()] == [400, 400, 400, 300]
    # MemoryStore speaks the same protocol (same windows, same rows).
    ws_d = list(st.iter_chunks(700))
    ws_m = list(MemoryStore(x, y).iter_chunks(700))
    assert len(ws_d) == len(ws_m)
    assert all(a[0] == b[0] and np.array_equal(a[1], b[1])
               for a, b in zip(ws_d, ws_m))


# -- streaming k-means ----------------------------------------------------


def test_single_batch_streaming_kmeans_is_lloyd(small):
    """With batch_rows >= n and per-epoch count resets, every epoch must
    reduce exactly to a Lloyd iteration (same partition of the data).
    The reference below re-implements Lloyd's M-step independently but
    shares the tiled assignment helper, so the claim under test is the
    mini-batch update algebra (per-epoch resets), not f32 tie-breaking."""
    from repro.data.streaming import _assign_chunk, streaming_kmeans_blocks

    x, y, _ = small
    beta = np.full(4, 0.5)
    k, epochs, seed = 24, 3, 3
    blocks, radii, vol = streaming_kmeans_blocks(
        MemoryStore(x, y), beta, k, seed=seed, epochs=epochs,
        batch_rows=10_000,
    )

    # Reference Lloyd with the identical init draw.
    xs = x / beta
    rng = np.random.default_rng(seed)
    centers = xs[rng.choice(len(xs), size=k, replace=False)]
    for _ in range(epochs):
        lab = _assign_chunk(xs, centers, np.sum(centers * centers, 1))
        for j in range(k):
            if np.any(lab == j):
                centers[j] = xs[lab == j].mean(axis=0)
    lab = _assign_chunk(xs, centers, np.sum(centers * centers, 1))

    # Same partition up to the coordinate relabeling the streaming path
    # applies for gather locality.
    for j in np.unique(lab):
        assert np.unique(blocks.labels[lab == j]).size == 1
    assert blocks.n_blocks == np.unique(lab).size
    # Radii bound every member distance to its final center.
    for b in range(blocks.n_blocks):
        mb = blocks.members[b]
        r = np.sqrt(np.max(np.sum((xs[mb] - blocks.centers[b]) ** 2, axis=1)))
        assert r <= radii[b] + 1e-12
    assert vol > 0


def test_streaming_kmeans_disk_equals_memory(tmp_path, small):
    from repro.data.streaming import streaming_kmeans_blocks

    x, y, _ = small
    st = ArrayStore.from_arrays(str(tmp_path / "k"), x, y, shard_rows=317)
    beta = np.asarray([0.05, 0.05, 5.0, 5.0])
    a = streaming_kmeans_blocks(MemoryStore(x, y), beta, 30, seed=1,
                                batch_rows=256)
    b = streaming_kmeans_blocks(st, beta, 30, seed=1, batch_rows=256)
    assert np.array_equal(a[0].labels, b[0].labels)
    assert np.array_equal(a[0].order, b[0].order)
    assert np.array_equal(a[0].centers, b[0].centers)
    assert np.array_equal(a[1], b[1]) and a[2] == b[2]


# -- fit parity ------------------------------------------------------------


def test_streaming_fit_store_equals_incore(tmp_path, small):
    """Disk-backed == RAM-backed, bit for bit (covers the spool round-trip
    and the gather/remap packing)."""
    x, y, _ = small
    st = ArrayStore.from_arrays(str(tmp_path / "f"), x, y, shard_rows=412)
    cfg = SBVConfig(n_blocks=24, m=20, seed=0)
    kw = dict(inner_steps=8, outer_rounds=2, stream_chunk=400)
    r_disk = fit_sbv(st, None, cfg, **kw)
    r_mem = fit_sbv(x, y, cfg, **kw)
    assert _params_equal(r_disk.params, r_mem.params) == 0.0
    assert [h[2] for h in r_disk.history] == [h[2] for h in r_mem.history]
    assert r_disk.stream_stats["n_chunks"] > 1


def test_chunked_fit_matches_monolithic_1e10(small):
    """Chunked grad accumulation vs the single-chunk program: identical
    structure (struct batch is decoupled from stream_chunk), so only the
    float summation order differs."""
    x, y, _ = small
    cfg = SBVConfig(n_blocks=24, m=20, seed=0)
    r_one = fit_sbv(x, y, cfg, inner_steps=10, outer_rounds=2,
                    stream_chunk=100_000)
    r_many = fit_sbv(x, y, cfg, inner_steps=10, outer_rounds=2,
                     stream_chunk=300)
    assert r_many.stream_stats["n_chunks"] > 3
    assert _params_equal(r_one.params, r_many.params) <= 1e-10


def test_bucketed_streaming_fit_matches_uniform(small):
    """Per-chunk bucketed dispatch (docs/packing.md) rides the streaming
    path unchanged: identity padding keeps per-block terms exact."""
    x, y, _ = small
    cfg = SBVConfig(n_blocks=24, m=20, seed=0)
    r_u = fit_sbv(x, y, cfg, inner_steps=6, outer_rounds=1, stream_chunk=400)
    r_b = fit_sbv(x, y, cfg, inner_steps=6, outer_rounds=1, stream_chunk=400,
                  n_buckets=3)
    assert _params_equal(r_u.params, r_b.params) <= 1e-10


# -- inner-loop memory tiers (device cache / prefetch / disk) --------------


def test_device_cache_matches_disk_spool_bitwise(small):
    """Pieces held in the device-resident spool tier across all inner
    steps must produce the identical fit to pieces re-read from the disk
    spool every step — the tier is pure residency, zero numerics."""
    x, y, _ = small
    cfg = SBVConfig(n_blocks=24, m=20, seed=0)
    kw = dict(inner_steps=6, outer_rounds=2, stream_chunk=300)
    r_dev = fit_sbv(x, y, cfg, device_cache=1 << 30, prefetch=0, **kw)
    r_disk = fit_sbv(x, y, cfg, device_cache=0, prefetch=0, **kw)
    st_dev, st_disk = r_dev.stream_stats, r_disk.stream_stats
    assert st_dev["n_pieces"] > 1
    assert st_dev["device_cached_pieces"] == st_dev["n_pieces"]
    assert st_dev["h2d_bytes_per_step"] == 0
    assert st_disk["device_cached_pieces"] == 0
    assert st_disk["h2d_bytes_per_step"] > 0
    assert _params_equal(r_dev.params, r_disk.params) == 0.0
    assert [h[2] for h in r_dev.history] == [h[2] for h in r_disk.history]


def test_prefetched_pipeline_matches_sync_bitwise(small):
    """The H2D producer thread stages disk pieces ahead of the device but
    preserves accumulation order — prefetched == synchronous, bitwise."""
    x, y, _ = small
    cfg = SBVConfig(n_blocks=24, m=20, seed=0)
    kw = dict(inner_steps=6, outer_rounds=2, stream_chunk=300, device_cache=0)
    r_pre = fit_sbv(x, y, cfg, prefetch=2, **kw)
    r_sync = fit_sbv(x, y, cfg, prefetch=0, **kw)
    assert r_pre.stream_stats["n_pieces"] > 1
    assert _params_equal(r_pre.params, r_sync.params) == 0.0
    assert [h[2] for h in r_pre.history] == [h[2] for h in r_sync.history]


def test_mixed_tier_spool_matches_disk_bitwise(small):
    """A budget that fits only part of the round: leading pieces stay on
    device, the overflow spools to disk — same fit, bitwise."""
    x, y, _ = small
    cfg = SBVConfig(n_blocks=24, m=20, seed=0)
    kw = dict(inner_steps=4, outer_rounds=1, stream_chunk=300)
    probe = fit_sbv(x, y, cfg, inner_steps=1, outer_rounds=1,
                    stream_chunk=300, device_cache=0)
    budget = probe.stream_stats["spool_bytes"] // 2
    r_mix = fit_sbv(x, y, cfg, device_cache=budget, **kw)
    r_disk = fit_sbv(x, y, cfg, device_cache=0, **kw)
    st = r_mix.stream_stats
    assert 0 < st["device_cached_pieces"] < st["n_pieces"]
    assert 0 < st["h2d_bytes_per_step"] < st["spool_bytes"]
    assert _params_equal(r_mix.params, r_disk.params) == 0.0


def test_streaming_auto_backend_resolves(small):
    """backend='auto' no longer raises: each spooled piece resolves
    through kernels.ops.select_backend; at these small shapes that is
    'ref', so the fit must match the explicit-ref fit bitwise."""
    from repro.kernels.ops import select_backend

    x, y, _ = small
    cfg = SBVConfig(n_blocks=48, m=10, seed=0)
    kw = dict(inner_steps=4, outer_rounds=1, stream_chunk=300)
    r_auto = fit_sbv(x, y, cfg, backend="auto", **kw)
    r_ref = fit_sbv(x, y, cfg, backend="ref", **kw)
    bs_max = r_auto.stream_stats["bs_max"]
    assert select_backend(bs_max, cfg.m, kind="loglik") == "ref"
    assert _params_equal(r_auto.params, r_ref.params) == 0.0


def test_chunk_grad_fn_cached_across_rounds():
    """The jitted chunk-grad wrapper is shared across outer rounds (and
    fits): same key -> same wrapper object -> one jit compile cache."""
    from repro.core.fit import _chunk_grad_fn

    assert _chunk_grad_fn(3.5, "ref", 1234) is _chunk_grad_fn(3.5, "ref", 1234)
    assert _chunk_grad_fn(3.5, "ref", 1234) is not _chunk_grad_fn(3.5, "ref", 999)
    assert _chunk_grad_fn(3.5, "ref", 1234) is not _chunk_grad_fn(3.5, "pallas", 1234)


def test_prefetcher_propagates_errors_and_closes():
    """The shared double-buffer primitive surfaces producer exceptions in
    the consumer and joins its thread on early exit."""
    import threading

    from repro.prefetch import Prefetcher

    def boom():
        yield 1
        raise RuntimeError("producer failed")

    with Prefetcher(boom(), depth=1) as pf:
        it = iter(pf)
        assert next(it) == 1
        with pytest.raises(RuntimeError, match="producer failed"):
            next(it)

    # early close unblocks a producer stuck on a full queue
    pf = Prefetcher(iter(range(100)), depth=1, stage=lambda i: i * 2)
    got = [next(iter(pf))]
    pf.close()
    assert got == [0]
    assert not any(t.name == "prefetch" and t.is_alive()
                   for t in threading.enumerate())


# -- distributed streaming (subprocess, 8 virtual devices) -----------------


STREAM_DIST_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from repro.core.fit import fit_sbv
    from repro.core.pipeline import SBVConfig
    from repro.data.gp_sim import paper_synthetic

    assert jax.device_count() == 8, jax.device_count()
    mesh = jax.make_mesh((8,), ("workers",))

    x, y, _ = paper_synthetic(seed=0, n=600, d=4)
    cfg = SBVConfig(n_blocks=24, m=16, n_workers=8, seed=0)
    kw = dict(inner_steps=8, outer_rounds=2, stream_chunk=200)

    def dparams(a, b):
        return max(np.abs(np.asarray(getattr(a.params, f)) -
                          np.asarray(getattr(b.params, f))).max()
                   for f in ("log_sigma2", "log_beta", "log_nugget"))

    r_ser = fit_sbv(x, y, cfg, **kw)
    r_dist = fit_sbv(x, y, cfg, distributed=(mesh, "workers"), **kw)
    d = dparams(r_ser, r_dist)
    assert d <= 1e-8, d
    assert r_dist.stream_stats["n_shards"] == 8
    assert r_dist.stream_stats["n_pieces"] > 1

    # the H2D pipeline stages sharded pieces too: disk tier + prefetch
    # under the mesh == device-cached under the mesh, bitwise
    r_disk = fit_sbv(x, y, cfg, distributed=(mesh, "workers"),
                     device_cache=0, prefetch=2, **kw)
    assert dparams(r_dist, r_disk) == 0.0

    losses = [h[2] for h in r_dist.history]
    assert losses[-1] < losses[0], losses
    print("STREAM_DIST_OK", d)
    """
)


def test_distributed_streaming_fit_matches_serial():
    """fit_sbv(stream_chunk=..., distributed=(mesh, axis)) on an 8-device
    mesh matches the serial streaming fit (same harness as
    tests/test_distributed_gp.py — the main process must keep 1 device)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", STREAM_DIST_SCRIPT], capture_output=True,
        text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, timeout=600,
    )
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert "STREAM_DIST_OK" in r.stdout


# -- predict parity --------------------------------------------------------


def test_streaming_predict_store_equals_incore(tmp_path, small):
    x, y, params = small
    st = ArrayStore.from_arrays(str(tmp_path / "p"), x, y, shard_rows=412)
    rng = np.random.default_rng(5)
    xt = rng.uniform(size=(300, 4))
    kw = dict(bs_pred=16, m_pred=48, n_sims=4, chunk_size=128,
              stream_chunk=400, seed=0)
    p_disk = predict_sbv(params, st, None, xt, **kw)
    p_mem = predict_sbv(params, x, y, xt, **kw)
    for f in ("mean", "var", "sim_mean", "ci_low", "ci_high"):
        assert np.array_equal(getattr(p_disk, f), getattr(p_mem, f)), f
    # Store-backed x_test rides the same chunk protocol.
    st_t = ArrayStore.from_arrays(str(tmp_path / "pt"), xt, np.zeros(300),
                                  shard_rows=90)
    p_both = predict_sbv(params, st, None, st_t, **kw)
    assert np.array_equal(p_both.mean, p_mem.mean)


def test_streaming_predict_matches_exact_gp(small):
    """m_pred >= n: every block conditions on the whole training set, so
    the streaming index must reproduce the exact GP like the in-core path
    does (the oracle test for the store-backed kNN + gather/remap)."""
    from repro.core.exact_gp import exact_predict

    x, y, params = small
    x, y = x[:400], y[:400]
    rng = np.random.default_rng(2)
    xt = rng.uniform(size=(60, 4))
    pred = predict_sbv(params, x, y, xt, bs_pred=8, m_pred=400, n_sims=2,
                       stream_chunk=150, chunk_size=60)
    em, ev = exact_predict(params, x, y, xt)
    np.testing.assert_allclose(pred.mean, np.asarray(em), atol=1e-4, rtol=0)
    np.testing.assert_allclose(pred.var, np.asarray(ev), atol=1e-4, rtol=0)


def test_pipeline_store_producer_matches_sync(tmp_path, small):
    """Serving pipeline with a store-backed test set: the producer thread
    reads windows from disk; results must equal the in-core sync loop
    bitwise (same chunk protocol underneath)."""
    from repro.core.predict import build_train_index
    from repro.serving import PipelineConfig, predict_pipelined, predict_synchronous

    x, y, params = small
    rng = np.random.default_rng(9)
    xt = rng.uniform(size=(500, 4))
    st_t = ArrayStore.from_arrays(str(tmp_path / "q"), xt, np.zeros(500),
                                  shard_rows=128)
    index = build_train_index(x, y, np.asarray(params.beta), 48, seed=0)
    cfg = PipelineConfig(bs_pred=16, m_pred=48, chunk_size=160)
    m_sync, v_sync = predict_synchronous(params, index, xt, cfg, seed=0)
    m_disk, v_disk = predict_pipelined(params, index, st_t, cfg, seed=0)
    assert np.array_equal(m_sync, m_disk) and np.array_equal(v_sync, v_disk)


# -- bounded-memory smoke fit ---------------------------------------------


def _vmrss_kb():
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmRSS:"):
                    return int(line.split()[1])
    except OSError:
        return None
    return None


@pytest.mark.slow
def test_rss_bounded_200k_fit(tmp_path):
    """200k-point store-backed smoke fit under a working-set RSS ceiling
    derived from the run's own streaming state (the small sibling of
    benchmarks/fig_streaming_scale.py's 1M gate)."""
    if _vmrss_kb() is None:
        pytest.skip("no /proc/self/status on this platform")
    import threading

    n, d, stream_chunk = 200_000, 16, 32_768
    rng = np.random.default_rng(0)
    with ArrayStore.create(str(tmp_path / "big"), d) as w:
        for _ in range(n // 20_000):
            xw = rng.uniform(size=(20_000, d))
            yw = np.sin(3 * xw[:, 0]) + xw[:, 1] ** 2 + 0.05 * rng.standard_normal(20_000)
            w.append(xw, yw)
    st = ArrayStore(str(tmp_path / "big"))

    peak = {"kb": _vmrss_kb()}
    base_kb = peak["kb"]
    stop = threading.Event()

    def poll():
        while not stop.is_set():
            kb = _vmrss_kb()
            if kb and kb > peak["kb"]:
                peak["kb"] = kb
            stop.wait(0.005)

    th = threading.Thread(target=poll, daemon=True)
    th.start()
    try:
        cfg = SBVConfig(n_blocks=n // 128, m=12, alpha=8.0, seed=0)
        res = fit_sbv(st, None, cfg, inner_steps=2, outer_rounds=1,
                      stream_chunk=stream_chunk)
    finally:
        stop.set()
        th.join(timeout=5)

    assert np.all(np.isfinite([h[2] for h in res.history]))
    from repro.data.streaming import working_set_model

    ws = working_set_model(res.stream_stats, n, d, cfg.m, stream_chunk,
                           n_caches=1)  # fit only — no predict index here
    budget = 2 * ws["total"]
    incore = ws["incore_total"]
    assert budget < incore, "ceiling must undercut the in-core footprint"
    delta = (peak["kb"] - base_kb) * 1024
    assert delta <= budget, (
        f"peak RSS delta {delta / 2**20:.0f}MB exceeded the 2x working-set "
        f"ceiling {budget / 2**20:.0f}MB (in-core would be ~{incore / 2**20:.0f}MB)"
    )


# -- streaming data-plane regression fixes ---------------------------------
# Each test below pins a latent bug found in the PR-6 sweep; each FAILED
# on the pre-fix code.


def test_lazy_flat_blocks_duplicate_ids_accounted_once(tmp_path, small):
    """Duplicate uncached block ids in ONE call are gathered and accounted
    once. Pre-fix, each duplicate re-gathered the block's rows and bumped
    ``_cache_bytes`` for a copy the cache never retained — the counter
    inflated permanently and drove the LRU into premature eviction."""
    from repro.data.streaming import LazyFlatBlocks, streaming_kmeans_blocks

    x, y, _ = small
    st = ArrayStore.from_arrays(str(tmp_path / "lz"), x, y, shard_rows=400)
    beta = np.full(4, 0.5)
    blocks, radii, _ = streaming_kmeans_blocks(st, beta, 12, seed=0)
    flat = LazyFlatBlocks(blocks, radii, st, beta)

    out = flat.points_of_blocks(np.array([3, 3, 5, 3]))
    # The stacked result still repeats block 3 per request...
    assert out.shape == (3 * flat.sizes[3] + flat.sizes[5], 4)
    # ...but each miss was read from the store exactly once,
    assert flat.gathered_rows == flat.sizes[3] + flat.sizes[5]
    # and the byte counter equals what the cache actually retains.
    assert flat._cache_bytes == sum(v.nbytes for v in flat._cache.values())

    # Accounting stays exact across repeats and cache hits.
    flat.points_of_blocks(np.array([5, 3, 5]))
    assert flat._cache_bytes == sum(v.nbytes for v in flat._cache.values())
    assert flat.gathered_rows == flat.sizes[3] + flat.sizes[5]


def _tiny_packed():
    from repro.core.packing import PackedBlocks

    bc, bs, m, d = 2, 3, 2, 2
    return PackedBlocks(
        blk_x=np.zeros((bc, bs, d)), blk_y=np.zeros((bc, bs)),
        blk_mask=np.ones((bc, bs), bool), nn_x=np.zeros((bc, m, d)),
        nn_y=np.zeros((bc, m)), nn_mask=np.ones((bc, m), bool),
        owners=np.zeros(bc, np.int32))


def test_spool_reusable_after_cleanup(tmp_path):
    """A spool must accept adds again after ``cleanup()``: the multi-round
    fit reuses per-round spool paths. Pre-fix, ``cleanup`` removed the
    directory but left ``_made_dir`` set, so the next overflow-to-disk
    ``add`` crashed in ``np.savez`` with FileNotFoundError — and the tier
    gauges kept counting entries that no longer existed."""
    from repro.data.streaming import PackedChunkSpool

    sp = PackedChunkSpool(str(tmp_path / "sp"), device_budget=0)
    sp.add(_tiny_packed())
    assert sp.n_disk == 1 and sp.disk_bytes_total > 0
    sp.cleanup()
    assert len(sp) == 0
    assert sp.device_bytes == 0 and sp.disk_bytes_total == 0

    sp.add(_tiny_packed())  # pre-fix: FileNotFoundError here
    pieces = list(sp.iter_arrays(prefetch=0))
    assert len(pieces) == 1
    assert np.asarray(pieces[0][0][0]).shape == (2, 3, 2)
    sp.cleanup()
    assert not os.path.exists(sp.path)


def test_streaming_moments_survive_large_offset(tmp_path):
    """Variance of y with ``|mean| >> std`` (a 1e8 offset leaves ~1e-1
    significant digits in the one-pass ``E[y^2] - mean^2`` form, which
    pre-fix collapsed to the clamp at 0 and silently initialized
    ``sigma2 ~ 0``). The shifted two-pass form keeps full precision, and
    both store backends still agree bitwise."""
    from repro.data.streaming import streaming_moments

    rng = np.random.default_rng(0)
    x = rng.uniform(size=(4000, 3))
    y = 1e8 + rng.standard_normal(4000)
    mean, var = streaming_moments(MemoryStore(x, y), batch_rows=700)
    assert np.isclose(mean, y.mean(), rtol=1e-12)
    assert np.isclose(var, y.var(), rtol=1e-9)

    st = ArrayStore.from_arrays(str(tmp_path / "mo"), x, y, shard_rows=512)
    m_disk, v_disk = streaming_moments(st, batch_rows=700)
    assert mean == m_disk and var == v_disk


def test_prefetcher_iteration_terminates_after_close():
    """Iterating a closed (or exception-drained) Prefetcher must return,
    not block forever on an empty queue. Pre-fix, ``__iter__`` sat in a
    bare ``q.get()`` with no producer left to feed it — a consumer that
    resumed iteration after ``close()`` hung the fit."""
    import threading

    from repro.prefetch import Prefetcher

    pf = Prefetcher(iter(range(100)), depth=1)
    it = iter(pf)
    assert next(it) == 0
    pf.close()

    got = {"done": False}

    def drain():
        list(it)  # pre-fix: blocks forever
        got["done"] = True

    th = threading.Thread(target=drain, daemon=True)
    th.start()
    th.join(timeout=10.0)
    assert got["done"], "iteration did not terminate after close()"

    # An exception consumed mid-stream leaves the thread dead and the
    # queue empty — later iteration must also terminate (idempotent).
    def boom():
        raise RuntimeError("producer failed")
        yield  # pragma: no cover

    pf2 = Prefetcher(boom(), depth=1)
    with pytest.raises(RuntimeError, match="producer failed"):
        next(iter(pf2))
    assert list(iter(pf2)) == []
    pf2.close()


def test_rows_view_scalar_indexing(tmp_path, small):
    """``view[5]`` must follow ndarray semantics and drop the row axis —
    pre-fix it returned ``(1, d)``/``(1,)``, which silently broadcast
    wrong shapes into consumers written against in-core arrays."""
    x, y, _ = small
    st = ArrayStore.from_arrays(str(tmp_path / "rv"), x, y, shard_rows=400)
    xv, yv = st.x_rows, st.y_rows

    assert xv[5].shape == (4,)
    assert np.array_equal(xv[5], x[5])
    assert np.ndim(yv[5]) == 0 and yv[5] == y[5]
    # negative indices normalize like ndarray
    assert np.array_equal(xv[-1], x[-1]) and yv[-1] == y[-1]
    # array/slice paths keep the row axis
    assert xv[np.array([5])].shape == (1, 4)
    assert xv[10:12].shape == (2, 4)
    with pytest.raises(IndexError):
        xv[len(xv)]
    with pytest.raises(IndexError):
        yv[-len(yv) - 1]


def test_working_set_model_terms(small):
    """The RSS-gate model must stay tied to real run state: every term
    positive, and the streaming budget strictly under the in-core cost
    for the shapes the gates actually use."""
    from repro.data.streaming import working_set_model

    x, y, _ = small
    cfg = SBVConfig(n_blocks=24, m=20, seed=0)
    res = fit_sbv(x, y, cfg, inner_steps=2, outer_rounds=1, stream_chunk=300)
    ws = working_set_model(res.stream_stats, len(y), 4, cfg.m, 300)
    assert all(v > 0 for v in ws["terms"].values())
    assert ws["total"] == sum(ws["terms"].values())
