"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
asserting output shapes + finiteness (the full configs are exercised only
via the dry-run)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.models import init_params, lm_loss, make_empty_cache, prefill_step, serve_step

ARCH_IDS = sorted(ARCHS)


def _toks(key, cfg, b=2, s=64):
    return jax.random.randint(key, (b, s), 0, cfg.vocab, dtype=jnp.int32)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_loss_finite_and_grad_flows(arch):
    cfg = get_config(arch).reduced(dtype="float32")
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    toks = _toks(key, cfg)
    labels = jnp.roll(toks, -1, axis=1)
    loss, grads = jax.jit(jax.value_and_grad(lambda p: lm_loss(p, toks, labels, cfg)))(params)
    assert np.isfinite(float(loss)), (arch, float(loss))
    # loss ~ log(vocab) at init
    assert 0.5 * np.log(cfg.vocab) < float(loss) < 2.5 * np.log(cfg.vocab), float(loss)
    leaves = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(l, dtype=np.float32))) for l in leaves)
    gnorm = sum(float(jnp.sum(jnp.square(l.astype(jnp.float32)))) for l in leaves)
    assert gnorm > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode_matches_full_forward(arch):
    """Greedy next-token from (prefill + decode) == argmax of a longer
    prefill — the KV/state cache is consistent with the parallel form."""
    cfg = get_config(arch).reduced(dtype="float32")
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    b, s = 2, 32
    toks = _toks(key, cfg, b, s + 1)

    logits_direct, _ = jax.jit(
        lambda p, t: prefill_step(p, t, cfg, cache_len=s + 8)
    )(params, toks)

    logits_pre, cache = jax.jit(
        lambda p, t: prefill_step(p, t, cfg, cache_len=s + 8)
    )(params, toks[:, :s])
    logits_dec, cache = jax.jit(
        lambda p, t, c: serve_step(p, t, c, cfg)
    )(params, toks[:, s : s + 1], cache)

    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_direct), rtol=2e-3, atol=2e-3
    )


def test_empty_cache_decode_runs():
    cfg = get_config("zamba2-2.7b").reduced(dtype="float32")
    params = init_params(jax.random.PRNGKey(2), cfg)
    cache = make_empty_cache(params, cfg, batch=2, cache_len=64)
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, cache2 = jax.jit(lambda p, t, c: serve_step(p, t, c, cfg))(params, tok, cache)
    assert logits.shape == (2, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))
