"""Shared-structure multi-output (VPPE) invariants — docs/multioutput.md.

The load-bearing contracts:

* p=1 is BITWISE the single-output path: a ``(n, 1)`` observation matrix
  squeezes into exactly the code that ran before multi-output existed,
  for fit and predict both.
* Batched p-output math equals p independent single-output passes on the
  SAME structure to relative 1e-8 (observed ~1e-13): the per-output
  likelihood vector, the profiled sigma2, and the prediction columns.
* The fused Pallas multi-stats kernel matches the vmapped reference
  (values and gradients), and bucketed stats match the uniform layout.
* The streaming multi fit is chunking-invariant, ``MultiOutputParams``
  survive the checkpoint round-trip, and the server computes all outputs
  once while per-request masks slice result columns.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.fit import fit_sbv
from repro.core.multioutput import (
    MultiOutputParams, as_multi_params, multi_loglik, packed_multi_stats,
    with_profiled_sigma2,
)
from repro.core.pipeline import SBVConfig, preprocess
from repro.core.predict import predict_sbv
from repro.core.vecchia import packed_loglik
from repro.data.store import MemoryStore

pytestmark = pytest.mark.multioutput

REL = 1e-8  # per-output parity is relative: ll magnitudes reach ~1e5


@pytest.fixture(scope="module")
def multi_problem():
    rng = np.random.default_rng(0)
    n, d, p = 500, 3, 3
    x = rng.uniform(size=(n, d))
    y = np.stack(
        [np.sin(x @ rng.uniform(1.0, 3.0, size=d))
         + 0.01 * rng.standard_normal(n) for _ in range(p)],
        axis=1,
    )
    return x, y


@pytest.fixture(scope="module")
def cfg():
    return SBVConfig(n_blocks=16, m=20, seed=0)


@pytest.fixture(scope="module")
def fitted(multi_problem, cfg):
    x, y = multi_problem
    return fit_sbv(x, y, cfg, inner_steps=4, outer_rounds=1)


def _rel(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return float(np.max(np.abs(a - b) / np.maximum(np.abs(b), 1.0)))


# -- p=1 bitwise identity --------------------------------------------------


def test_p1_fit_is_bitwise_single_output(multi_problem, cfg):
    x, y = multi_problem
    res1 = fit_sbv(x, y[:, 0], cfg, inner_steps=3, outer_rounds=1)
    res2 = fit_sbv(x, y[:, :1], cfg, inner_steps=3, outer_rounds=1)
    for f in ("log_sigma2", "log_beta", "log_nugget"):
        assert np.array_equal(np.asarray(getattr(res1.params, f)),
                              np.asarray(getattr(res2.params, f))), f
    assert np.array_equal(np.asarray(res1.history), np.asarray(res2.history))


def test_p1_predict_is_bitwise_single_output(multi_problem, cfg):
    x, y = multi_problem
    res = fit_sbv(x, y[:, 0], cfg, inner_steps=3, outer_rounds=1)
    xq = np.random.default_rng(5).uniform(size=(40, x.shape[1]))
    p1 = predict_sbv(res.params, x, y[:, 0], xq, bs_pred=8, m_pred=24, seed=0)
    p2 = predict_sbv(res.params, x, y[:, :1], xq, bs_pred=8, m_pred=24, seed=0)
    assert p2.mean.shape == (40, 1) and p2.var.shape == (40, 1)
    for f in ("mean", "var", "sim_mean", "ci_low", "ci_high"):
        assert np.array_equal(np.asarray(getattr(p1, f)),
                              np.asarray(getattr(p2, f))[:, 0]), f


# -- batched == p independent single-output passes on shared structure ----


def test_multi_loglik_matches_per_output_singles(multi_problem, cfg, fitted):
    x, y = multi_problem
    params = fitted.params
    packed_m, _ = preprocess(x, y, params.beta, cfg)
    ll_multi = np.asarray(multi_loglik(params, packed_m))
    ll_single = np.array([
        float(packed_loglik(params.output_params(j),
                            preprocess(x, y[:, j], params.beta, cfg)[0]))
        for j in range(y.shape[1])
    ])
    assert _rel(ll_multi, ll_single) <= REL


def test_multi_stats_match_stacked_single_output_packs(multi_problem, cfg,
                                                       fitted):
    x, y = multi_problem
    params = fitted.params
    packed_m, _ = preprocess(x, y, params.beta, cfg)
    ld_m, q_m = packed_multi_stats(params, packed_m)
    for j in range(y.shape[1]):
        packed_j, _ = preprocess(x, y[:, j : j + 1], params.beta, cfg)
        ld_j, q_j = packed_multi_stats(params, packed_j)
        assert abs(float(ld_j) - float(ld_m)) <= REL * abs(float(ld_m))
        assert _rel(q_j[0], q_m[j]) <= REL


def test_profiled_sigma2_matches_per_output_profile(multi_problem, cfg,
                                                    fitted):
    x, y = multi_problem
    params = fitted.params
    packed_m, _ = preprocess(x, y, params.beta, cfg)
    prof = with_profiled_sigma2(params, packed_m)
    for j in range(y.shape[1]):
        packed_j, _ = preprocess(x, y[:, j : j + 1], params.beta, cfg)
        _, q_j = packed_multi_stats(params, packed_j)
        s2_j = float(q_j[0]) / packed_j.n_points
        assert abs(float(prof.sigma2[j]) - s2_j) <= REL * abs(s2_j)


def test_multi_predict_matches_per_output_singles(multi_problem, cfg, fitted):
    x, y = multi_problem
    params = fitted.params
    xq = np.random.default_rng(7).uniform(size=(50, x.shape[1]))
    pm = predict_sbv(params, x, y, xq, bs_pred=8, m_pred=24, seed=0, n_sims=2)
    assert pm.mean.shape == (50, y.shape[1])
    for j in range(y.shape[1]):
        pj = predict_sbv(params.output_params(j), x, y[:, j], xq,
                         bs_pred=8, m_pred=24, seed=0, n_sims=2)
        assert _rel(pm.mean[:, j], pj.mean) <= REL
        assert _rel(pm.var[:, j], pj.var) <= REL
    assert np.all(np.asarray(pm.var) > 0)


# -- kernels: fused Pallas multi-stats == vmapped reference ----------------


def test_pallas_multi_stats_matches_ref(multi_problem, cfg, fitted):
    x, y = multi_problem
    params = fitted.params
    packed_m, _ = preprocess(x, y, params.beta, cfg)
    ld_r, q_r = packed_multi_stats(params, packed_m, backend="ref")
    ld_p, q_p = packed_multi_stats(params, packed_m, backend="pallas")
    assert abs(float(ld_p) - float(ld_r)) <= 1e-8 * max(abs(float(ld_r)), 1.0)
    assert _rel(q_p, q_r) <= REL


def test_pallas_multi_stats_gradients_match_ref(multi_problem, cfg, fitted):
    x, y = multi_problem
    params = fitted.params
    packed_m, _ = preprocess(x, y, params.beta, cfg)

    def loss(pp, backend):
        ld, q = packed_multi_stats(pp, packed_m, backend=backend)
        return ld + jnp.sum(jnp.log(q))

    g_r = jax.grad(lambda pp: loss(pp, "ref"))(params)
    g_p = jax.grad(lambda pp: loss(pp, "pallas"))(params)
    for f in ("log_sigma2", "log_beta", "log_tau2"):
        assert np.allclose(np.asarray(getattr(g_p, f)),
                           np.asarray(getattr(g_r, f)),
                           rtol=1e-8, atol=1e-10), f
    # The pooled objective never touches log_sigma2 (it is profiled out):
    # its gradient through the stats must be exactly zero.
    g_pool = jax.grad(
        lambda pp: packed_multi_stats(pp, packed_m)[0]
        + jnp.sum(packed_multi_stats(pp, packed_m)[1])
    )(params)
    assert np.all(np.asarray(g_pool.log_sigma2) == 0.0)


def test_bucketed_multi_stats_match_uniform(multi_problem, cfg, fitted):
    from repro.core.buckets import bucket_blocks

    x, y = multi_problem
    params = fitted.params
    packed_m, _ = preprocess(x, y, params.beta, cfg)
    ld_u, q_u = packed_multi_stats(params, packed_m)
    ld_b, q_b = packed_multi_stats(params, bucket_blocks(packed_m, n_buckets=3))
    assert abs(float(ld_b) - float(ld_u)) <= 1e-10 * max(abs(float(ld_u)), 1.0)
    assert _rel(q_b, q_u) <= 1e-10


# -- streaming fit ---------------------------------------------------------


def test_streaming_multi_fit_chunking_invariant(multi_problem, cfg):
    x, y = multi_problem
    res_a = fit_sbv(x, y, cfg, inner_steps=3, outer_rounds=1,
                    stream_chunk=120)
    res_b = fit_sbv(x, y, cfg, inner_steps=3, outer_rounds=1,
                    stream_chunk=5000)
    for f in ("log_sigma2", "log_beta", "log_tau2"):
        assert np.allclose(np.asarray(getattr(res_a.params, f)),
                           np.asarray(getattr(res_b.params, f)),
                           rtol=0, atol=1e-10), f
    assert res_a.stream_stats["n_outputs"] == y.shape[1]


def test_store_backed_multi_fit_routes_to_streaming(multi_problem, cfg):
    x, y = multi_problem
    store = MemoryStore(x, y)
    res_st = fit_sbv(store, None, cfg, inner_steps=3, outer_rounds=1,
                     stream_chunk=120)
    res_in = fit_sbv(x, y, cfg, inner_steps=3, outer_rounds=1,
                     stream_chunk=120)
    for f in ("log_sigma2", "log_beta", "log_tau2"):
        assert np.array_equal(np.asarray(getattr(res_st.params, f)),
                              np.asarray(getattr(res_in.params, f))), f


def test_multi_fit_rejects_unsupported_paths(multi_problem, cfg):
    x, y = multi_problem
    with pytest.raises(NotImplementedError):
        fit_sbv(x, y, cfg, distributed=(None, "workers"))
    with pytest.raises(NotImplementedError):
        fit_sbv(x, y, cfg, stream_chunk=120, n_buckets=2)


# -- mixed-precision multi fits (ladder is cast-only on packed dtypes) -----


def test_multi_precision_nll_within_tier_budget(multi_problem, cfg, fitted):
    """``cast_packed`` composes with multi-RHS columns directly: at every
    tier, each output's multi-batched ll equals the single-output ll of
    the SAME tier-cast data (the batching adds only ulp-class noise, no
    new error class), and the widest narrow tier stays inside its
    documented budget vs f64. (The f32 rung's 1e-6 budget is what the
    single-output PROBE enforces by demotion — the cast-only multi path
    inherits the raw cast error, identical to the single-output raw cast
    error, which is the composition claim.)"""
    from repro.core.buckets import _TIER_BUDGETS, cast_packed

    x, y = multi_problem
    params = fitted.params
    packed, _ = preprocess(x, y, np.asarray(params.beta), cfg)
    for tier in ("f32", "bf16"):
        ll_multi = np.asarray(multi_loglik(params, cast_packed(packed, tier)),
                              dtype=np.float64)
        for j in range(y.shape[1]):
            pk_j = preprocess(x, y[:, j], np.asarray(params.beta), cfg)[0]
            ll_j = float(packed_loglik(params.output_params(j),
                                       cast_packed(pk_j, tier)))
            rel = abs(ll_multi[j] - ll_j) / max(1.0, abs(ll_j))
            # ulp-class batching noise at the tier's accumulation width
            assert rel <= {"f32": 1e-5, "bf16": 5e-5}[tier], (tier, j, rel)
    ref = np.asarray(multi_loglik(params, cast_packed(packed, "f64")),
                     dtype=np.float64)
    got = np.asarray(multi_loglik(params, cast_packed(packed, "bf16")),
                     dtype=np.float64)
    rel = np.max(np.abs(got - ref) / np.maximum(1.0, np.abs(ref)))
    assert rel <= _TIER_BUDGETS["bf16"], rel


def test_multi_precision_fit_parity_vs_f64(multi_problem, cfg):
    """End-to-end f32 multi fit lands within the tier's budget of the
    f64 fit: identical structure passes and step counts, so the only
    divergence is the cast — compare the fits' pooled objectives at
    their own optima (the ladder's deployed-quality contract)."""
    x, y = multi_problem
    res64 = fit_sbv(x, y, cfg, inner_steps=4, outer_rounds=1)
    res32 = fit_sbv(x, y, cfg, inner_steps=4, outer_rounds=1,
                    precision="f32")
    nll64 = res64.history[-1][2]
    nll32 = res32.history[-1][2]
    assert abs(nll32 - nll64) / max(1.0, abs(nll64)) <= 1e-4
    for f in ("log_beta", "log_tau2", "log_sigma2"):
        a = np.asarray(getattr(res32.params, f), dtype=np.float64)
        b = np.asarray(getattr(res64.params, f), dtype=np.float64)
        assert np.allclose(a, b, rtol=0, atol=1e-2), f


def test_multi_precision_bucketed_and_streaming_paths(multi_problem, cfg):
    """Precision composes with the bucketed in-core multi fit and the
    streaming multi fit (uniform cast before spooling, recorded in
    stream_stats)."""
    x, y = multi_problem
    res_b = fit_sbv(x, y, cfg, inner_steps=2, outer_rounds=1,
                    precision="f32", n_buckets=2)
    res_s = fit_sbv(x, y, cfg, inner_steps=2, outer_rounds=1,
                    precision="f32", stream_chunk=120)
    assert res_s.stream_stats["precision"] == "f32"
    res64 = fit_sbv(x, y, cfg, inner_steps=2, outer_rounds=1)
    for res in (res_b, res_s):
        for f in ("log_beta", "log_tau2"):
            a = np.asarray(getattr(res.params, f), dtype=np.float64)
            b = np.asarray(getattr(res64.params, f), dtype=np.float64)
            assert np.allclose(a, b, rtol=0, atol=1e-2), f


# -- parameter container + checkpoint round-trip ---------------------------


def test_as_multi_params_roundtrip():
    from repro.core.kernels_math import KernelParams

    kp = KernelParams.create(sigma2=2.0, beta=np.array([1.0, 2.0]),
                             nugget=1e-3)
    mp = as_multi_params(kp, p=4, d=2)
    assert mp.n_outputs == 4
    assert np.allclose(np.asarray(mp.sigma2), 2.0)
    assert np.allclose(np.asarray(mp.tau2), 1e-3 / 2.0)
    back = mp.output_params(2)
    for f in ("log_sigma2", "log_beta", "log_nugget"):
        assert np.allclose(np.asarray(getattr(back, f)),
                           np.asarray(getattr(kp, f))), f
    assert as_multi_params(mp, p=4, d=2) is mp


def test_multi_params_checkpoint_roundtrip(tmp_path, fitted):
    from repro.ckpt.checkpoint import restore_train_state, save_checkpoint

    params = fitted.params
    path = save_checkpoint(str(tmp_path), 0, {"params": params})
    state, _ = restore_train_state(path, {"params": params})
    restored = state["params"]
    assert isinstance(restored, MultiOutputParams)
    for f in ("log_sigma2", "log_beta", "log_tau2"):
        assert np.array_equal(np.asarray(getattr(restored, f)),
                              np.asarray(getattr(params, f))), f


# -- serving: output masks -------------------------------------------------


def test_server_multi_output_and_masks(multi_problem, cfg, fitted):
    from repro.serving import GPServer, GPServerConfig, PipelineConfig
    from repro.serving.batching import SchedulerPolicy

    x, y = multi_problem
    params = fitted.params
    p = y.shape[1]
    xq = np.random.default_rng(11).uniform(size=(45, x.shape[1]))
    ref = predict_sbv(params, x, y, xq, bs_pred=8, m_pred=24, seed=0)

    pipe = PipelineConfig(bs_pred=8, m_pred=24, chunk_size=None)
    # Drain mode: the first batch reproduces predict_sbv; a masked
    # request's result is exactly the requested columns.
    with GPServer(params, x, y, GPServerConfig(pipeline=pipe)) as srv:
        assert srv.n_outputs == p
        fut = srv.submit(xq, outputs=[p - 1, 0])
        srv.flush()
        res = fut.result()
    assert res.mean.shape == (45, 2)
    np.testing.assert_array_equal(res.mean, ref.mean[:, [p - 1, 0]])
    np.testing.assert_array_equal(res.var, ref.var[:, [p - 1, 0]])

    # Scheduler mode: same contract through the continuous-batching path,
    # and a full-mask request collapses to the unmasked result.
    with GPServer(params, x, y,
                  GPServerConfig(pipeline=pipe,
                                 scheduler=SchedulerPolicy())) as srv:
        fut = srv.submit(xq, outputs=[1])
        srv.flush()
        r1 = fut.result()
        fut = srv.submit(xq, outputs=list(range(p)))
        srv.flush()
        r2 = fut.result()
    np.testing.assert_array_equal(r1.mean, ref.mean[:, [1]])
    assert r2.mean.shape == (45, p)

    with GPServer(params, x, y, GPServerConfig(pipeline=pipe)) as srv:
        with pytest.raises(ValueError):
            srv.submit(xq, outputs=[p])
        with pytest.raises(ValueError):
            srv.submit(xq, outputs=[])


def test_spool_sink_multi_output_roundtrip(multi_problem, cfg, fitted):
    from repro.serving import GPServer, GPServerConfig, PipelineConfig
    from repro.serving.batching import SchedulerPolicy

    x, y = multi_problem
    params = fitted.params
    xq = np.random.default_rng(13).uniform(size=(40, x.shape[1]))
    ref = predict_sbv(params, x, y, xq, bs_pred=8, m_pred=24, seed=0)
    pipe = PipelineConfig(bs_pred=8, m_pred=24, chunk_size=None)
    with GPServer(params, x, y,
                  GPServerConfig(pipeline=pipe,
                                 scheduler=SchedulerPolicy(
                                     spool_threshold=1))) as srv:
        fut = srv.submit(xq)
        srv.flush()
        res = fut.result()
    assert res.mean is None and res.sink is not None
    mean, var = res.sink.materialize()
    np.testing.assert_array_equal(mean, ref.mean)
    np.testing.assert_array_equal(var, ref.var)
    res.sink.cleanup()


# -- dataset generator -----------------------------------------------------


def test_metarvm_field_dataset_shapes_and_endpoint():
    from repro.data.gp_sim import (metarvm_dataset, metarvm_field_dataset,
                                   metarvm_field_simulate,
                                   metarvm_sample_inputs)

    x, y = metarvm_field_dataset(0, 64, p=5)
    assert x.shape == (64, 10) and y.shape == (64, 5)
    assert np.allclose(y.mean(axis=0), 1.0)  # per-output normalization
    # The last snapshot is exactly the single-output simulator endpoint.
    theta = metarvm_sample_inputs(0, 64)
    field = metarvm_field_simulate(theta, p=5)
    x1, y1 = metarvm_dataset(0, 64, normalize=False)
    assert np.array_equal(field[:, -1], y1)
    # Cumulative admissions are monotone across snapshots.
    assert np.all(np.diff(field, axis=1) >= 0)
