"""TokenStream determinism / checkpointability / sharding invariants."""
from __future__ import annotations

import numpy as np

from _hypothesis_compat import given, settings, st  # skips @given tests if absent

from repro.data.tokens import TokenStream


def test_deterministic_replay():
    a = TokenStream(1000, 8, 32, seed=5)
    b = TokenStream(1000, 8, 32, seed=5)
    for _ in range(3):
        ta, la = a.next()
        tb, lb = b.next()
        np.testing.assert_array_equal(ta, tb)
        np.testing.assert_array_equal(la, lb)


def test_state_roundtrip_resumes_exactly():
    a = TokenStream(1000, 8, 32, seed=5)
    a.next(); a.next()
    saved = a.state_dict()
    want_t, want_l = a.next()
    b = TokenStream(1000, 8, 32, seed=0)
    b.load_state_dict(saved)
    got_t, got_l = b.next()
    np.testing.assert_array_equal(want_t, got_t)
    np.testing.assert_array_equal(want_l, got_l)


def test_labels_are_shifted_tokens():
    s = TokenStream(1000, 4, 16, seed=1)
    t, l = s.next()
    np.testing.assert_array_equal(t[:, 1:], l[:, :-1])


@settings(max_examples=20, deadline=None)
@given(nw=st.sampled_from([1, 2, 4, 8]), idx=st.integers(0, 5))
def test_shards_partition_the_global_batch(nw, idx):
    """Concatenating all worker shards == the full unsharded batch."""
    full = TokenStream(500, 8, 16, seed=9, start_batch=idx)
    ft, fl = full.next()
    parts = []
    for w in range(nw):
        s = TokenStream(500, 8, 16, seed=9, start_batch=idx)
        parts.append(s.next(shard=(w, nw))[0])
    np.testing.assert_array_equal(np.concatenate(parts, axis=0), ft)
