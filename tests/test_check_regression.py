"""The benchmark-regression gate itself (benchmarks/check_regression.py).

Every CI gate that compares a fresh smoke run against a committed
baseline routes through this one script, so its tolerance directions are
load-bearing: a 'time' metric that treated slower as better, or a
'bound' that silently skipped a missing metric, would turn every
benchmark gate green forever. Tier-1 (no marker): the gate logic is pure
Python and must stay correct even when the specialty gates are skipped.
"""
import json

import pytest

import benchmarks.check_regression as cr
from benchmarks.check_regression import (SPECS, Metric, check_benchmark,
                                         lookup, main)


def _statuses(rows):
    return {(spec.path, spec.kind): status for spec, status, _ in rows}


def _one(name, fresh, base):
    rows = check_benchmark(name, fresh, base)
    assert len(rows) == len(cr.SPECS[name])
    return _statuses(rows)


# -- path lookup -----------------------------------------------------------


def test_lookup_dotted_and_row_paths():
    payload = {
        "a": {"b": 3.0},
        "rows": [{"path": "sync", "time_s": 1.0},
                 {"path": "loglik/bucketed", "time_s": 2.0}],
    }
    assert lookup(payload, "a.b") == 3.0
    assert lookup(payload, "rows[path=sync].time_s") == 1.0
    assert lookup(payload, "rows[path=loglik/bucketed].time_s") == 2.0
    assert lookup(payload, "a.missing") is None
    assert lookup(payload, "rows[path=nope].time_s") is None
    assert lookup(payload, "missing.b") is None


# -- tolerance directions, one kind at a time ------------------------------


@pytest.fixture()
def spec_sandbox(monkeypatch):
    """Install a minimal spec so direction tests don't depend on the real
    benchmark schemas."""
    specs = {
        "toy": [
            Metric("t", "time", tol=0.10),
            Metric("quality", "floor", tol=0.10),
            Metric("rss", "ceiling", tol=0.10),
            Metric("parity", "bound", bound=1e-8),
            Metric("noisy", "floor", tol=0.10, warn_only=True),
            Metric("opt", "ceiling", tol=0.10, gated_by="opt_measured"),
        ]
    }
    monkeypatch.setattr("benchmarks.check_regression.SPECS", specs)
    return specs


def _toy(t=1.0, quality=1.0, rss=1.0, parity=0.0, noisy=1.0, opt=1.0,
         opt_measured=True, calib_s=1.0, **extra):
    return dict(t=t, quality=quality, rss=rss, parity=parity, noisy=noisy,
                opt=opt, opt_measured=opt_measured, calib_s=calib_s, **extra)


def test_all_equal_is_all_ok(spec_sandbox):
    st = _one("toy", _toy(), _toy())
    assert set(st.values()) == {"OK"}


def test_time_regression_fails_improvement_warns(spec_sandbox):
    assert _one("toy", _toy(t=1.2), _toy())[("t", "time")] == "FAIL"
    assert _one("toy", _toy(t=0.8), _toy())[("t", "time")] == "WARN"
    # within tolerance either way: OK
    assert _one("toy", _toy(t=1.05), _toy())[("t", "time")] == "OK"


def test_time_is_normalized_by_calib_s(spec_sandbox):
    # 2x slower wall time on a 2x slower host is NOT a regression...
    st = _one("toy", _toy(t=2.0, calib_s=2.0), _toy(t=1.0, calib_s=1.0))
    assert st[("t", "time")] == "OK"
    # ...but without calib_s in both payloads, raw seconds are compared.
    st = _one("toy", _toy(t=2.0, calib_s=None), _toy(t=1.0, calib_s=1.0))
    assert st[("t", "time")] == "FAIL"


def test_floor_drop_fails_rise_warns(spec_sandbox):
    assert _one("toy", _toy(quality=0.8), _toy())[("quality", "floor")] == "FAIL"
    assert _one("toy", _toy(quality=1.2), _toy())[("quality", "floor")] == "WARN"


def test_ceiling_growth_fails_shrink_warns(spec_sandbox):
    assert _one("toy", _toy(rss=1.2), _toy())[("rss", "ceiling")] == "FAIL"
    assert _one("toy", _toy(rss=0.8), _toy())[("rss", "ceiling")] == "WARN"


def test_bound_is_absolute_and_baseline_independent(spec_sandbox):
    # The baseline value is irrelevant — only fresh vs the hard bound.
    base = _toy(parity=1.0)  # terrible baseline must not excuse the fresh run
    assert _one("toy", _toy(parity=1e-9), base)[("parity", "bound")] == "OK"
    assert _one("toy", _toy(parity=1e-6), base)[("parity", "bound")] == "FAIL"


def test_warn_only_regression_never_fails(spec_sandbox):
    assert _one("toy", _toy(noisy=0.5), _toy())[("noisy", "floor")] == "WARN"


def test_gated_by_false_skips(spec_sandbox):
    st = _one("toy", _toy(opt=99.0, opt_measured=False), _toy())
    assert st[("opt", "ceiling")] == "SKIP"


def test_missing_fresh_metric_fails_missing_baseline_skips(spec_sandbox):
    fresh = _toy()
    del fresh["t"], fresh["parity"]
    st = _one("toy", fresh, _toy())
    assert st[("t", "time")] == "FAIL"          # relative kinds
    assert st[("parity", "bound")] == "FAIL"    # bounds too: absent != passing
    base = _toy()
    del base["quality"]
    assert _one("toy", _toy(), base)[("quality", "floor")] == "SKIP"


# -- every committed spec resolves against its committed baseline ----------


def test_committed_baselines_satisfy_their_specs():
    import os

    from benchmarks.check_regression import BASELINE_DIR

    for name, specs in SPECS.items():
        path = os.path.join(BASELINE_DIR, f"{name}.json")
        if not os.path.exists(path):
            continue  # gate not armed yet — CI prints the arming hint
        with open(path) as f:
            payload = json.load(f)
        rows = check_benchmark(name, payload, payload)
        bad = [(s.path, st, d) for s, st, d in rows if st == "FAIL"]
        assert not bad, f"{name}: committed baseline fails its own gate: {bad}"


def test_fig7_multioutput_gate_is_armed():
    """PRs 4/6 shipped gates whose baselines were swallowed by the
    benchmarks/results/* ignore rule — pin that the new baseline is
    actually tracked and self-consistent."""
    import os
    import subprocess

    from benchmarks.check_regression import BASELINE_DIR

    path = os.path.join(BASELINE_DIR, "fig7_multioutput.json")
    assert os.path.exists(path), "multioutput gate baseline missing"
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    ignored = subprocess.run(
        ["git", "check-ignore", "-q", path], cwd=repo).returncode == 0
    assert not ignored, "baseline is gitignored — the gate would never arm"
    with open(path) as f:
        payload = json.load(f)
    assert payload["cost_ratio_multi_vs_independent"] < 0.5
    assert payload["ll_parity_rel"] <= 1e-8
    assert payload["predict_parity_rel"] <= 1e-8


# -- CLI behavior ----------------------------------------------------------


def _write(dirpath, name, payload):
    p = dirpath / f"{name}.json"
    p.write_text(json.dumps(payload))
    return p


def test_main_missing_fresh_file_fails(tmp_path, capsys):
    fresh = tmp_path / "fresh"
    fresh.mkdir()
    rc = main(["--fresh", str(fresh), "--baseline", str(tmp_path),
               "fig7_multioutput"])
    assert rc == 1
    assert "missing" in capsys.readouterr().out


def test_main_missing_baseline_is_not_a_failure(tmp_path, capsys):
    fresh = tmp_path / "fresh"
    fresh.mkdir()
    _write(fresh, "fig7_multioutput", {
        "cost_ratio_multi_vs_independent": 0.1,
        "ll_parity_rel": 1e-12, "predict_parity_rel": 1e-12,
        "rows": [{"path": "multi", "time_s": 1.0}], "calib_s": 1.0,
    })
    base = tmp_path / "base"
    base.mkdir()
    rc = main(["--fresh", str(fresh), "--baseline", str(base),
               "fig7_multioutput"])
    assert rc == 0
    assert "commit" in capsys.readouterr().out  # the arming hint


def test_main_write_baseline_round_trip(tmp_path, capsys):
    fresh = tmp_path / "fresh"
    fresh.mkdir()
    base = tmp_path / "base"
    base.mkdir()
    payload = {
        "cost_ratio_multi_vs_independent": 0.1,
        "ll_parity_rel": 1e-12, "predict_parity_rel": 1e-12,
        "rows": [{"path": "multi", "time_s": 1.0}], "calib_s": 1.0,
    }
    _write(fresh, "fig7_multioutput", payload)
    rc = main(["--fresh", str(fresh), "--baseline", str(base),
               "--write-baseline", "fig7_multioutput"])
    assert rc == 0
    with open(base / "fig7_multioutput.json") as f:
        assert json.load(f) == payload
    # The refreshed baseline immediately gates a matching fresh run green.
    rc = main(["--fresh", str(fresh), "--baseline", str(base),
               "fig7_multioutput"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "all gates passed" in out
    # ...and a bound violation in a later fresh run turns it red.
    bad = dict(payload, ll_parity_rel=1e-3)
    _write(fresh, "fig7_multioutput", bad)
    rc = main(["--fresh", str(fresh), "--baseline", str(base),
               "fig7_multioutput"])
    assert rc == 1
