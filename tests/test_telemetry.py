"""Direct unit coverage for ``serving/telemetry.py`` (ISSUE satellite):
percentile snapshot math, occupancy accounting, compile-shape set growth
(per-bucket, tier-tagged keys — the router's affinity signal), and the
counter reset semantics. Unmarked on purpose: pure-python, tier-1."""
import numpy as np

from repro.serving.telemetry import RequestTrace, ServerStats, _percentile


def _trace(n_points, submit, dispatch, done):
    t = RequestTrace(n_points=n_points, t_submit=submit)
    t.t_dispatch = dispatch
    t.t_done = done
    return t


# -- percentile snapshot math ----------------------------------------------


def test_percentile_nearest_rank_math():
    vals = [0.1, 0.2, 0.3, 0.4, 0.5]
    assert _percentile([], 0.99) == 0.0
    assert _percentile(vals, 0.0) == 0.1
    assert _percentile(vals, 0.5) == 0.3
    assert _percentile(vals, 1.0) == 0.5
    # q*(len-1) rounds to the nearest rank and clamps at the top
    assert _percentile(vals, 0.95) == 0.5
    assert _percentile([7.0], 0.99) == 7.0


def test_latency_percentiles_and_class_windows():
    stats = ServerStats(window=8)
    lat = [0.010, 0.020, 0.030, 0.040, 0.100]
    for i, el in enumerate(lat):
        stats.record_request(_trace(5, t0 := float(i), t0 + 0.001, t0 + el),
                             slo="interactive" if i < 4 else "bulk")
    s = stats.summary()
    assert s["n_requests"] == 5
    assert s["n_points"] == 25
    assert abs(s["latency_p50_s"] - 0.030) < 1e-12
    assert abs(s["latency_p99_s"] - 0.100) < 1e-12
    assert abs(s["queue_wait_p50_s"] - 0.001) < 1e-12
    assert s["by_class"]["interactive"]["n"] == 4
    assert s["by_class"]["bulk"]["n"] == 1
    assert abs(s["by_class"]["bulk"]["latency_p99_s"] - 0.100) < 1e-12


def test_window_bounds_percentile_samples_not_counters():
    stats = ServerStats(window=4)
    for i in range(10):
        stats.record_request(_trace(1, 0.0, 0.0, float(i + 1)))
    s = stats.summary()
    assert s["n_requests"] == 10           # counters are lifetime-exact
    assert len(stats.latencies_s) == 4     # samples are windowed
    assert s["latency_p50_s"] >= 8.0       # only the newest 4 remain


# -- occupancy accounting --------------------------------------------------


def test_occupancy_accumulates_ratio_terms():
    stats = ServerStats()
    assert stats.summary()["padding_occupancy"] == 1.0  # no data = no waste
    stats.record_occupancy(30.0, 60.0)
    stats.record_occupancy(10.0, 20.0)
    assert abs(stats.summary()["padding_occupancy"] - 0.5) < 1e-12
    assert stats.true_flops == 40.0
    assert stats.padded_flops == 80.0


# -- compile-shape set growth (the affinity signal) ------------------------


def test_compiled_shapes_one_key_per_bucket_piece():
    """Regression for the bucketed-dispatch undercount: every bucket
    piece records its own key, and n_chunks still counts chunks."""
    stats = ServerStats()
    # one chunk that split into three bucket pieces
    stats.record_chunk_shape(8, 16, 32, count_chunk=True, tier="f64")
    stats.record_chunk_shape(8, 8, 64, count_chunk=False, tier="f64")
    stats.record_chunk_shape(16, 24, 96, count_chunk=False, tier="f64")
    assert stats.n_chunks == 1
    assert stats.summary()["n_compiled_shapes"] == 3


def test_compiled_shapes_key_includes_precision_tier():
    """Same (bc, bs, m) at two tiers is two compiled programs — and two
    keys."""
    stats = ServerStats()
    stats.record_chunk_shape(8, 16, 32, tier="f64")
    stats.record_chunk_shape(8, 16, 32, tier="f32")
    stats.record_chunk_shape(8, 16, 32, tier="f32")  # dedup within a tier
    assert stats.compiled_shape_keys() == {(8, 16, 32, "f64"),
                                           (8, 16, 32, "f32")}
    assert stats.summary()["n_compiled_shapes"] == 2


def test_pipeline_records_tier_tagged_keys_per_piece():
    """End-to-end: the chunk split's pieces land tier-tagged keys derived
    from their actual packed dtypes."""
    from repro.core.buckets import dtype_tier

    assert dtype_tier(np.float64) == "f64"
    assert dtype_tier(np.float32) == "f32"
    import jax.numpy as jnp

    assert dtype_tier(jnp.bfloat16) == "bf16"


def test_compiled_shape_keys_returns_a_snapshot():
    stats = ServerStats()
    stats.record_chunk_shape(8, 16, 32)
    snap = stats.compiled_shape_keys()
    stats.record_chunk_shape(16, 16, 32)
    assert len(snap) == 1
    assert len(stats.compiled_shape_keys()) == 2


# -- reset semantics -------------------------------------------------------


def test_reset_zeroes_counters_and_windows():
    stats = ServerStats()
    stats.record_request(_trace(10, 0.0, 0.1, 0.2), slo="interactive")
    stats.record_batch(2, 20)
    stats.record_chunk_shape(8, 16, 32, tier="f32")
    stats.record_occupancy(1.0, 2.0)
    stats.record_cancelled()
    stats.record_preemption()
    stats.record_rejected()
    stats.record_queue_depth(64)
    t0 = stats.t_start
    stats.reset()
    s = stats.summary()
    for k in ("n_requests", "n_points", "n_batches", "n_chunks",
              "n_cancelled", "n_preempted", "n_rejected",
              "queue_depth_points", "queue_depth_peak"):
        assert s[k] == 0, k
    assert s["latency_p50_s"] == 0.0
    assert s["by_class"] == {}
    assert s["padding_occupancy"] == 1.0
    assert stats.t_start >= t0  # qps clock restarted


def test_reset_preserves_compiled_shapes_by_default():
    """The process jit cache survives a stats reset, so the shape keys do
    too — unless explicitly cleared (fresh-server accounting)."""
    stats = ServerStats()
    stats.record_chunk_shape(8, 16, 32, tier="f64")
    stats.reset()
    assert stats.summary()["n_compiled_shapes"] == 1
    stats.reset(preserve_shapes=False)
    assert stats.summary()["n_compiled_shapes"] == 0
