"""Distributed SBV across 8 virtual workers + checkpointed MLE restart.

Demonstrates the production posture on CPU stand-in devices:
* blocks sharded by owner over a 'workers' mesh (the paper's MPI ranks),
* one scalar psum per iteration (audited from the compiled HLO),
* optimizer-state checkpointing -> kill -> elastic restore on a
  DIFFERENT worker count.

    PYTHONPATH=src python examples/distributed_fit.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np

from repro.analysis.hlo_cost import CostModel
from repro.core import SBVConfig, preprocess
from repro.core.distributed import distributed_neg_loglik_fn
from repro.core.kernels_math import KernelParams
from repro.ckpt import save_checkpoint, restore_train_state
from repro.data.gp_sim import paper_synthetic
from repro.launch.mesh import make_worker_mesh
from repro.optim import adam_init, adam_update

N, BS, M = 6_000, 20, 32

x, y, true_params = paper_synthetic(seed=0, n=N)
params = KernelParams.create(sigma2=float(np.var(y)), beta=0.5, nugget=1e-3,
                             d=x.shape[1])

# --- phase 1: 8 workers -------------------------------------------------
mesh8 = make_worker_mesh(8)
cfg = SBVConfig(n_blocks=N // BS, m=M, n_workers=8, seed=0)
packed, _ = preprocess(x, y, np.asarray(params.beta), cfg)
loss8 = distributed_neg_loglik_fn(packed, 3.5, mesh8, "workers")

cm = CostModel(loss8.lower(params).compile().as_text(), n_devices=8)
coll = cm.collective_bytes()
print(f"hot-path collectives on 8 workers: {coll['counts']}, "
      f"{coll['total']:.0f} wire bytes/iter — the paper's single MPI_Allreduce")

import jax

grad8 = jax.jit(jax.value_and_grad(loss8))
state = adam_init(params)
for it in range(15):
    loss_v, g = grad8(params)
    params, state = adam_update(g, state, params, 0.05)
print(f"after 15 steps on 8 workers: nll/n = {float(loss_v):.4f}")

ckpt_path = save_checkpoint("/tmp/sbv_ckpt", 15, {"params": params, "opt": state})
print(f"checkpointed -> {ckpt_path}")

# --- phase 2: elastic restart on 4 workers ------------------------------
mesh4 = make_worker_mesh(4)
cfg4 = SBVConfig(n_blocks=N // BS, m=M, n_workers=4, seed=0)
packed4, _ = preprocess(x, y, np.asarray(params.beta), cfg4)
loss4 = distributed_neg_loglik_fn(packed4, 3.5, mesh4, "workers")

restored, manifest = restore_train_state(
    ckpt_path, {"params": params, "opt": state})
params, state = restored["params"], restored["opt"]
print(f"restored step-{manifest['step']} state onto a 4-worker mesh (elastic)")

grad4 = jax.jit(jax.value_and_grad(loss4))
for it in range(15):
    loss_v, g = grad4(params)
    params, state = adam_update(g, state, params, 0.05)
print(f"after 15 more steps on 4 workers: nll/n = {float(loss_v):.4f}")
print("relevance 1/beta:", np.round(1 / np.asarray(params.beta), 2),
      "(dims 0-1 should dominate)")
