"""Train a ~100M-parameter LM for a few hundred steps (deliverable (b)).

Uses the public launch driver with a reduced-but-real config on a 1x1
mesh (pass --mesh 2x2 under XLA_FLAGS=--xla_force_host_platform_device_count=4
to exercise FSDP+TP on virtual devices).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse

from repro.configs import get_config
from repro.launch import train as train_mod
from repro.launch.param_count import total_param_count


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--mesh", default="1x1")
    args = ap.parse_args()

    # ~100M-class config: internlm2 family at 12 layers, d=768.
    over = dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                head_dim=64, d_ff=2048, vocab=32_000)
    cfg = get_config("internlm2-1.8b")
    reduced = cfg.reduced(**over)
    n = total_param_count(reduced)
    print(f"[train_lm] {reduced.name}: {n/1e6:.1f}M params, {args.steps} steps")

    train_mod.main([
        "--arch", "internlm2-1.8b", "--steps", str(args.steps),
        "--batch", "8", "--seq", "512", "--mesh", args.mesh,
        "--ckpt-dir", "/tmp/lm_ckpt", "--ckpt-every", "100", "--reduced",
    ] + [f"--override={k}={v}" for k, v in over.items()])


if __name__ == "__main__":
    main()
