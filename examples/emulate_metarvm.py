"""End-to-end emulator driver (paper §6.3): MetaRVM -> SBV surrogate.

Runs the full pipeline the paper describes: sample simulator inputs,
run the compartmental epidemic model, fit a distributed SBV GP, validate
held-out predictions, and report per-parameter relevance.

    PYTHONPATH=src python examples/emulate_metarvm.py [--n 20000] [--workers 4]
"""
import argparse
import time

import numpy as np

from repro.core import SBVConfig
from repro.core.fit import fit_sbv
from repro.core.predict import predict_sbv, rmspe
from repro.data.gp_sim import METARVM_BOUNDS, metarvm_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--m-est", type=int, default=40)
    ap.add_argument("--m-pred", type=int, default=80)
    args = ap.parse_args()

    print(f"[1/4] simulating {args.n} MetaRVM runs (100-day epidemic each)...")
    t0 = time.time()
    x, y = metarvm_dataset(seed=0, n=args.n)
    print(f"      {time.time()-t0:.1f}s; output mean-normalized hospitalizations")

    n_test = args.n // 10
    x_tr, y_tr = x[:-n_test], y[:-n_test]
    x_te, y_te = x[-n_test:], y[-n_test:]
    mu = y_tr.mean()

    print(f"[2/4] fitting SBV GP (bs=100-geometry, m_est={args.m_est}, "
          f"P={args.workers})...")
    distributed = None
    if args.workers > 1:
        from repro.launch.mesh import make_worker_mesh

        distributed = (make_worker_mesh(args.workers), "workers")
    cfg = SBVConfig(n_blocks=max(1, len(y_tr) // 100), m=args.m_est,
                    n_workers=args.workers, seed=0)
    t0 = time.time()
    res = fit_sbv(x_tr, y_tr - mu, cfg, inner_steps=40, outer_rounds=2,
                  distributed=distributed, verbose=True)
    print(f"      fit in {time.time()-t0:.1f}s")

    print(f"[3/4] predicting {n_test} held-out runs (bs_pred=25, "
          f"m_pred={args.m_pred})...")
    pred = predict_sbv(res.params, x_tr, y_tr - mu, x_te,
                       bs_pred=25, m_pred=args.m_pred)
    err = rmspe(pred.mean + mu, y_te)
    cover = float(np.mean((y_te - mu >= pred.ci_low) & (y_te - mu <= pred.ci_high)))
    print(f"      RMSPE {err:.2f}%   95% CI coverage {cover:.1%}")

    print("[4/4] parameter relevance (1/beta, paper Fig. 7b):")
    rel = 1.0 / np.asarray(res.params.beta)
    for name, r in sorted(zip(METARVM_BOUNDS, rel), key=lambda t: -t[1]):
        bar = "#" * int(40 * r / rel.max())
        print(f"      {name:>3s} {r:8.3f} {bar}")
    print("      (dh and dr should rank last — they don't drive "
          "cumulative admissions)")


if __name__ == "__main__":
    main()
