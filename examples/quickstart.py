"""Quickstart: fit a Scaled Block Vecchia GP in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import SBVConfig
from repro.core.fit import fit_sbv
from repro.core.predict import predict_sbv
from repro.data.gp_sim import paper_synthetic

# 1. Data: 10-d anisotropic GP draw (only dims 0-1 matter; paper §6.1).
x, y, true_params = paper_synthetic(seed=0, n=5_000)
x_train, y_train = x[:4_500], y[:4_500]
x_test, y_test = x[4_500:], y[4_500:]

# 2. Configure: ~90 blocks of ~50 points, 40 nearest neighbors per block.
cfg = SBVConfig(n_blocks=90, m=40, seed=0)

# 3. Fit by gradient MLE. The Scaled-Vecchia alternation rebuilds the
#    block/neighbor structure with the current anisotropy every round.
result = fit_sbv(x_train, y_train, cfg, inner_steps=100, outer_rounds=3,
                 lr=0.1, verbose=True)
print("estimated relevance 1/beta:", np.round(1 / np.asarray(result.params.beta), 2))
print("true relevance        :", np.round(1 / np.array([0.05, 0.05] + [5.0] * 8), 2))

# 4. Predict with conditional simulation (mean, variance, 95% CI).
pred = predict_sbv(result.params, x_train, y_train, x_test, bs_pred=5, m_pred=80)
mspe = float(np.mean((pred.mean - y_test) ** 2))
cover = float(np.mean((y_test >= pred.ci_low) & (y_test <= pred.ci_high)))
print(f"MSPE {mspe:.4f} (var(y)={y.var():.3f});  95% CI coverage {cover:.1%}")
assert mspe < 0.5 * y.var(), "GP should beat the mean predictor comfortably"
